"""Tracked emulator benchmark (BENCH_emulator.json) + paper Table 4.

Times the fast emulator engines at fleet scale against the reference
closure-based event loop (``engine="reference"``):

* ``fastpath/*``  — the vectorized calendar engine on fault-free traces
  (the paper's 20-node scale with 5k-batch traces, plus a 100-node fleet);
* ``eventpath/*`` — the flat (closure-free) event engine on a single-fault
  trace;
* ``replicated/*`` — warm-replica plans vs single-copy-plus-restore under
  the same primary-node kill; every run asserts the replicated p99 beats
  the restore path AND flat-event/reference metrics identity on the
  replicated plan (the replication-contract gate);
* ``sweep/*``     — Monte-Carlo (fault-seed x arrival-rate) grids on
  240-500 node clusters with 2k-50k-batch traces
  (``repro.emulator.sweep``).  ``--update`` times one scaled-down
  reference cell per grid, extrapolates linearly (events per batch are
  constant), and records the projection against the ``BUDGET_S`` budget:
  the largest grid (64 cells x 50k batches) is far beyond what the
  reference engine can finish and is marked DNF; the smaller fault grids
  stay within budget and are tracked for the event-path speedup.

Every timed fast run is asserted metrics-identical to the reference on the
spots where both are run (the equivalence contract, live).

Usage:
  python -m benchmarks.emulator_bench --update [--reps N]  # re-measure + write
  python -m benchmarks.emulator_bench --check  [--reps N]  # CI: fail on >2x
  python -m benchmarks.emulator_bench                      # print, no write

``--check`` re-times the fast engines only and fails when any entry's
best-of-reps exceeds CHECK_RATIO x the committed median (same tolerance and
methodology as benchmarks/planner_scale.py; regenerate on a uniformly
slower host rather than chasing phantom regressions).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from repro.configs.paper_cnns import PAPER_MODELS
from repro.core import (blob_cluster, grid_cluster, partition_and_place,
                        random_geometric_cluster, replicate_bottlenecks,
                        ring_cluster)
from repro.core.stageplan import from_seifer
from repro.emulator import (DriftingCluster, NodeFault, RandomNodeFaults,
                            compare_replan, evaluate_cells,
                            metrics_identical, plan_replicas,
                            plan_stage_args, simulate)
from repro.emulator.pipeline import emulate_plan

from .common import check_bench, load_bench, time_us

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_emulator.json")
CHECK_RATIO = 2.0           # --check fails on >2x regression vs committed
DEFAULT_REPS = 5
BUDGET_S = 30.0             # reference budget per sweep entry (projected)

# (key, model, cap, n_nodes, n_batches, arrival_rate_hz)
FASTPATH_CASES = [
    ("ResNet50/n20/b5000", "ResNet50", 30e6, 20, 5000, None),
    ("ResNet50/n100/b10000", "ResNet50", 30e6, 100, 10000, None),
    ("InceptionResNetV2/n20/b2000", "InceptionResNetV2", 30e6, 20, 2000,
     None),
]

# (key, model, cap, n_nodes, n_batches)  -- one mid-trace node kill+recover
EVENTPATH_CASES = [
    ("ResNet50/n20/b1000/kill1", "ResNet50", 30e6, 20, 1000),
]

# (key, model, cap, n_nodes, n_seeds, rates, n_batches, fault_model)
SWEEP_CASES = [
    ("ResNet50/n240/seeds32x2/b50000", "ResNet50", 30e6, 240, 32,
     (None, 4.0), 50000, None),
    ("ResNet50/n240/seeds16/b2000/kill1", "ResNet50", 30e6, 240, 16,
     (None,), 2000,
     RandomNodeFaults(n_faults=1, window_s=(10.0, 60.0),
                      recover_after_s=40.0)),
    ("ResNet50/n500/seeds8/b5000/kill2", "ResNet50", 30e6, 500, 8,
     (None,), 5000,
     RandomNodeFaults(n_faults=2, window_s=(10.0, 120.0),
                      recover_after_s=60.0)),
]

# replicated plan vs single-copy-plus-restore under the same primary-node
# kill: ``replicate_bottlenecks`` spends one spare on the costliest stage
# (best-connected spare), that stage's primary is killed, and the warm
# replica absorbs the outage with zero restore while the single-copy plan
# pays detection + checkpoint reschedule — so the replicated p99 must come
# out lower.  Asserted on every run (--update AND --check) together with
# flat-event-vs-reference metrics identity on the replicated plan — the
# replication-contract gate.
# (key, model, cap, n_nodes, n_seeds, n_batches, rate, kill_t)
REPLICATED_CASES = [
    ("ResNet50/n20/seeds8/b300/kill-primary", "ResNet50", 30e6, 20, 8,
     300, 2.0, 20.0),
]

# static plan vs replan-every-period on a drifting cluster
# (key, model, cap, n_nodes, period_s, horizon_s, rate_hz, seeds, drift);
# every run (--update AND --check) asserts replan p99 < static p99 — the
# closed-loop elastic-serving gate
REPLAN_CASES = [
    ("ResNet50/n20/drift2/p10", "ResNet50", 30e6, 20, 10.0, 80.0, 5.0,
     (0, 1, 2),
     DriftingCluster(decay_hops=2, decay_factor=0.55, decay_steps=4,
                     decay_every_s=10.0, jitter=0.1, slow_nodes=1,
                     slowdown_factor=0.4, start_s=5.0)),
]


def _plan_cache():
    plans: dict = {}

    def get(model, cap, n):
        key = (model, cap, n)
        if key not in plans:
            g = PAPER_MODELS[model]()
            cluster = random_geometric_cluster(n, rng=n)
            p = partition_and_place(g, cluster, cap, n_classes=3, rng=0)
            plans[key] = (cluster, p.placement.nodes,
                          p.partition.boundary_sizes,
                          p.partition.compute_flops)
        return plans[key]
    return get


def _assert_identical(a: dict, b: dict) -> None:
    assert metrics_identical(a, b), \
        "fast engine diverged from reference (equivalence contract)"


def measure(reps: int, with_naive: bool) -> dict:
    entries: dict[str, dict] = {}
    get = _plan_cache()

    for key, model, cap, n, nb, rate in FASTPATH_CASES:
        cluster, nodes, bounds, flops = get(model, cap, n)
        kw = dict(n_batches=nb, duration_s=1e9, arrival_rate_hz=rate, rng=0)

        def fast():
            return simulate(cluster, nodes, bounds, flops,
                            engine="calendar", **kw)
        med, lo = time_us(fast, reps)
        e = {"median_us": med, "min_us": lo}
        if with_naive:
            def ref():
                return simulate(cluster, nodes, bounds, flops,
                                engine="reference", **kw)
            e["naive_median_us"], _ = time_us(ref, reps)
            e["speedup"] = round(e["naive_median_us"] / e["median_us"], 2)
            _assert_identical(fast(), ref())
        entries[f"fastpath/{key}"] = e

    for key, model, cap, n, nb in EVENTPATH_CASES:
        cluster, nodes, bounds, flops = get(model, cap, n)
        faults = [NodeFault(20.0, nodes[1], recover_after_s=30.0)]
        kw = dict(n_batches=nb, duration_s=1e9, faults=faults, rng=0)

        def fast():
            return simulate(cluster, nodes, bounds, flops,
                            engine="events", **kw)
        med, lo = time_us(fast, reps)
        e = {"median_us": med, "min_us": lo}
        if with_naive:
            def ref():
                return simulate(cluster, nodes, bounds, flops,
                                engine="reference", **kw)
            e["naive_median_us"], _ = time_us(ref, reps)
            e["speedup"] = round(e["naive_median_us"] / e["median_us"], 2)
            _assert_identical(fast(), ref())
        entries[f"eventpath/{key}"] = e

    for key, model, cap, n, n_seeds, rates, nb, fm in SWEEP_CASES:
        cluster, nodes, bounds, flops = get(model, cap, n)
        n_cells = n_seeds * len(rates)

        def fast():
            return evaluate_cells(cluster, nodes, bounds, flops,
                                  seeds=range(n_seeds), arrival_rates=rates,
                                  n_batches=nb, fault_model=fm)
        med, lo = time_us(fast, reps)
        e = {"median_us": med, "min_us": lo, "cells": n_cells,
             "batches_per_cell": nb}
        if with_naive:
            # one scaled-down reference cell, extrapolated linearly: the
            # event count per batch is constant along the trace
            scale = 10
            t0 = time.perf_counter()
            simulate(cluster, nodes, bounds, flops,
                     n_batches=nb // scale, duration_s=1e9,
                     arrival_rate_hz=rates[-1],
                     faults=fm.draw(0, nodes) if fm else (),
                     rng=0, engine="reference")
            cell_s = (time.perf_counter() - t0) * scale
            projected = cell_s * n_cells
            e["naive_projected_s"] = round(projected, 1)
            e["naive_budget_s"] = BUDGET_S
            e["naive_status"] = ("DNF" if projected > BUDGET_S
                                 else "within-budget")
        entries[f"sweep/{key}"] = e

    for (key, model, cap, n, n_seeds, nb, rate, kt) in REPLICATED_CASES:
        g = PAPER_MODELS[model]()
        cluster = random_geometric_cluster(n, rng=n)
        sp = partition_and_place(g, cluster, cap, n_classes=3, rng=0)
        rp = replicate_bottlenecks(from_seifer(sp, cluster), cluster,
                                   budget=1, max_replicas=2)
        ks = next(k for k, s in enumerate(rp.stages) if s.replicas)
        nodes, bounds, flops = plan_stage_args(rp)
        replicas = plan_replicas(rp)
        faults = [NodeFault(kt, nodes[ks + 1])]     # primary, permanent
        kw = dict(n_batches=nb, duration_s=1e9, arrival_rate_hz=rate,
                  engine="events")

        def run_grid(reps_arg):
            return [simulate(cluster, nodes, bounds, flops, faults=faults,
                             rng=s, replicas=reps_arg, **kw)
                    for s in range(n_seeds)]

        def fast():
            return run_grid(replicas)
        med, lo = time_us(fast, reps)
        rep_p99 = max(m["p99_e2e_s"] for m in run_grid(replicas))
        single_p99 = max(m["p99_e2e_s"] for m in run_grid(None))
        assert rep_p99 < single_p99, (
            f"replicated/{key}: warm-replica p99 ({rep_p99:.4g}s) must beat "
            f"single-copy-plus-restore p99 ({single_p99:.4g}s) under the "
            f"same primary kill")
        _assert_identical(
            simulate(cluster, nodes, bounds, flops, faults=faults, rng=0,
                     replicas=replicas, **kw),
            simulate(cluster, nodes, bounds, flops, faults=faults, rng=0,
                     replicas=replicas, **{**kw, "engine": "reference"}))
        entries[f"replicated/{key}"] = {
            "median_us": med, "min_us": lo,
            "replicated_stage": ks,
            "replicated_p99_s": round(rep_p99, 5),
            "single_restore_p99_s": round(single_p99, 5),
            "p99_speedup": round(single_p99 / rep_p99, 2),
        }

    for (key, model, cap, n, period, horizon, rate, seeds,
         drift) in REPLAN_CASES:
        g = PAPER_MODELS[model]()
        cluster = random_geometric_cluster(n, rng=n)
        xp = from_seifer(partition_and_place(g, cluster, cap, n_classes=3,
                                             rng=0), cluster)

        def fast():
            return compare_replan(xp, cluster, drift=drift,
                                  period_s=period, horizon_s=horizon,
                                  arrival_rate_hz=rate, seeds=seeds)
        med, lo = time_us(fast, reps)
        out = fast()
        s_p99 = out["static"]["p99_e2e_s"]
        r_p99 = out["replan"]["p99_e2e_s"]
        assert r_p99 < s_p99, (
            f"replan/{key}: replan-every-{period}s p99 ({r_p99:.4g}s) must "
            f"beat static p99 ({s_p99:.4g}s) on the drifting cluster")
        entries[f"replan/{key}"] = {
            "median_us": med, "min_us": lo,
            "static_p99_s": round(s_p99, 5),
            "replan_p99_s": round(r_p99, 5),
            "p99_speedup": round(s_p99 / r_p99, 2),
            "moves": out["replan"]["moves"],
            "replanned_windows": out["replan"]["replanned_windows"],
        }
    return entries


def check(reps: int) -> int:
    return check_bench("emulator_bench", BENCH_PATH,
                       measure(reps, with_naive=False), CHECK_RATIO)


def update(reps: int) -> None:
    entries = measure(reps, with_naive=True)
    doc = {
        "meta": {
            "reps": reps,
            "tool": "benchmarks/emulator_bench.py --update",
            "note": ("median microseconds per call; naive = reference "
                     "closure-based event loop (sweep entries: one "
                     "scaled-down reference cell extrapolated linearly, "
                     f"DNF when projected > {BUDGET_S}s budget); --check "
                     f"compares best-of-reps with a {CHECK_RATIO}x ratio "
                     "tolerance"),
        },
        "entries": entries,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, e in sorted(entries.items()):
        if "naive_median_us" in e:
            extra = f"naive {e['naive_median_us']:.0f}us, x{e['speedup']}"
        elif "replicated_p99_s" in e:
            extra = (f"replicated p99 {e['replicated_p99_s']:.3g}s vs "
                     f"single+restore {e['single_restore_p99_s']:.3g}s, "
                     f"x{e['p99_speedup']}")
        elif "p99_speedup" in e:
            extra = (f"static p99 {e['static_p99_s']:.3g}s vs replan "
                     f"{e['replan_p99_s']:.3g}s, x{e['p99_speedup']}")
        else:
            extra = (f"naive projected {e.get('naive_projected_s', '?')}s "
                     f"({e.get('naive_status', '?')})")
        print(f"{name}: {e['median_us']:.0f}us ({extra})")


# ---------------------------------------------------------------------------
# benchmarks.run entry point: Table 4 + tracked timings
# ---------------------------------------------------------------------------

def make_cluster(shape: str, n: int):
    if shape == "ring":
        return ring_cluster(n)
    if shape == "grid":
        rows = int(np.sqrt(n))
        while n % rows:
            rows -= 1
        return grid_cluster(rows, n // rows)
    if shape == "cluster":
        return blob_cluster(n, n_blobs=max(2, n // 4))
    return random_geometric_cluster(n, rng=n)


def run(reps: int = 3):
    """Paper Table 4 (ring/grid/cluster at 5/9/20 nodes, via the fast
    engine, now with 2k-batch traces) extended with fleet-scale geometric
    clusters, plus the tracked timing entries."""
    rows = []
    g = PAPER_MODELS["ResNet50"]()
    table4 = ([(s, n, 2000) for n in (5, 9, 20)
               for s in ("ring", "grid", "cluster")]
              + [("geo", 100, 10000), ("geo", 240, 10000),
                 ("geo", 500, 10000)])
    for shape, n, nb in table4:
        cluster = make_cluster(shape, n)
        try:
            plan = partition_and_place(g, cluster, 64e6, n_classes=3, rng=0)
            t0 = time.perf_counter()
            m = emulate_plan(plan, cluster, None, nb, 1e9)
            us = (time.perf_counter() - t0) * 1e6
            rows.append({"name": f"emulator/{shape}/n{n}/throughput_hz",
                         "us_per_call": us,
                         "derived": round(m["throughput_hz"], 4)})
            rows.append({"name": f"emulator/{shape}/n{n}/e2e_s",
                         "us_per_call": us,
                         "derived": round(m["mean_e2e_s"], 2)})
        except Exception as e:
            rows.append({"name": f"emulator/{shape}/n{n}",
                         "us_per_call": 0.0,
                         "derived": f"infeasible({type(e).__name__})"})
    committed = load_bench(BENCH_PATH) or {"entries": {}}
    for name, e in measure(reps, with_naive=False).items():
        c = committed["entries"].get(name, {})
        derived = c.get("speedup", c.get("naive_status", ""))
        rows.append({"name": f"emulator_bench/{name}",
                     "us_per_call": e["median_us"],
                     "derived": f"committed={derived}"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="measure fast + reference, write BENCH_emulator.json")
    ap.add_argument("--check", action="store_true",
                    help=f"fail on >{CHECK_RATIO}x regression vs committed")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    reps = args.reps or (DEFAULT_REPS if (args.update or args.check) else 3)
    if args.update:
        update(reps)
    elif args.check:
        sys.exit(check(reps))
    else:
        for r in run(reps):
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
