"""HLO cost walker: per-device FLOPs / traffic / collective bytes from
optimized (post-SPMD) HLO text, with while-loop bodies multiplied by their
parsed trip counts.

Why not XLA's own analysis (``repro.compat.cost_analysis``): it visits a while
body once, so scan-over-layers models under-report by ~n_layers (measured
9.4x for mamba2-1.3b).  This walker:

  * parses every computation into {name -> instruction} with result shapes,
  * resolves while-loop trip counts from the loop condition's comparison
    constant,
  * counts dot FLOPs (2 * prod(output) * prod(contracting dims)) including
    dots inside fused computations,
  * counts collective wire bytes with standard ring-algorithm factors,
  * approximates HBM traffic as sum(output bytes + operand bytes) of
    non-trivial ops (post-fusion HLO, so fusion boundaries ~ materialization
    boundaries).

Cross-validated against compat.cost_analysis on loop-free modules
(tests/test_hlo_cost.py).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[^=(]+?)\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str           # raw remainder of the line (operands + attrs)

    def shapes(self):
        return _SHAPE_RE.findall(self.type_str)

    def result_bytes(self) -> float:
        total = 0.0
        for dt, dims in self.shapes():
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            total += n * _DTYPE_BYTES.get(dt, 2)
        return total

    def result_dims(self):
        """dims of the first tensor in the result type."""
        m = _SHAPE_RE.search(self.type_str)
        if not m:
            return []
        dims = m.group(2)
        return [int(d) for d in dims.split(",")] if dims else []

    def operand_names(self):
        # operands are leading %names in rest, before the closing paren
        depth, i = 1, 0
        while i < len(self.rest) and depth > 0:
            if self.rest[i] == "(":
                depth += 1
            elif self.rest[i] == ")":
                depth -= 1
            i += 1
        inner = self.rest[:i - 1] if depth == 0 else self.rest
        return re.findall(r"%[\w.\-]+", inner)

    def attr(self, key: str):
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_list(self, key: str):
        m = re.search(rf"{key}={{([\d,\s]*)}}", self.rest)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x.strip()]


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)
    order: list = field(default_factory=list)

    def root(self):
        return self.instrs[self.order[-1]] if self.order else None


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            ins = Instr(name=m.group(1).lstrip("%"), type_str=m.group(2),
                        op=m.group(3), rest=m.group(4))
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    return comps


def _group_size(rest: str, default: int) -> int:
    # replica_groups=[G,K]<=[T] (iota form) or explicit {{0,1},{2,3}}
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for d in ins.result_dims():
        out_elems *= d
    lhs_names = ins.operand_names()
    contract = ins.attr_list("lhs_contracting_dims")
    if not lhs_names:
        return 0.0
    lhs = comp.instrs.get(lhs_names[0].lstrip("%"))
    cdim = 1
    if lhs is not None:
        ldims = lhs.result_dims()
        for ci in contract:
            if ci < len(ldims):
                cdim *= ldims[ci]
    return 2.0 * out_elems * max(cdim, 1)


def _while_trip_count(ins: Instr, comps: dict) -> int:
    cond_name = ins.attr("condition")
    cond = comps.get(cond_name) if cond_name else None
    if cond is None:
        return 1
    consts = []
    for i in cond.instrs.values():
        if i.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + i.rest)
            if m:
                consts.append(int(m.group(1)))
    # loop bounds are the largest compare constant; bodies typically count
    # 0..N-1 with direction LT
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


# ops excluded from the bytes-accessed proxy (free or bookkeeping).
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "reshape", "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier"}

# ops counted by OUTPUT bytes only: on TPU these fuse into their consumers
# (dtype converts, layout moves) or touch only the addressed window
# (slice/gather); XLA-CPU materializes them, which would otherwise inflate
# the memory term by the full operand size (measured 2.5x+ on decode cells).
_OUTPUT_ONLY_OPS = {"convert", "slice", "copy", "transpose", "broadcast",
                    "iota", "pad", "reverse", "concatenate", "gather",
                    "dynamic-slice", "exponential", "select", "compare"}

# in-place window writers: traffic ~ 2x the update window (read-modify-write),
# not the full destination array (TPU donates and updates in place).
_WINDOW_WRITE_OPS = {"dynamic-update-slice", "scatter"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


@dataclass
class Cost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict = None
    collective_counts: dict = None

    def __post_init__(self):
        if self.collective_bytes is None:
            self.collective_bytes = {k: 0.0 for k in COLLECTIVE_OPS}
        if self.collective_counts is None:
            self.collective_counts = {k: 0 for k in COLLECTIVE_OPS}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        for k in COLLECTIVE_OPS:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _wire_bytes(ins: Instr, kind: str) -> float:
    size = ins.result_bytes()
    g = _group_size(ins.rest, 2)
    if kind == "all-gather":
        return size * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * size * (g - 1) / g
    if kind == "reduce-scatter":
        # result is the scattered shard; wire ~ (g-1) * shard
        return size * (g - 1)
    if kind == "all-to-all":
        return size * (g - 1) / g
    return size            # collective-permute


def _io_bytes(ins: Instr, comp: Computation) -> float:
    total = ins.result_bytes()
    for opn in ins.operand_names():
        src = comp.instrs.get(opn.lstrip("%"))
        if src is not None:
            total += src.result_bytes()
    return total


def cost_of(comp: Computation, comps: dict, memo: dict) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    memo[comp.name] = c          # breaks cycles (none expected)
    for ins in comp.instrs.values():
        if ins.op == "while":
            body = comps.get(ins.attr("body"))
            trips = _while_trip_count(ins, comps)
            if body is not None:
                c.add(cost_of(body, comps, memo), trips)
            # the while's own tuple shuffling is negligible
            continue
        if ins.op in ("call", "conditional"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "branch_computations"):
                sub = ins.attr(key)
                if sub and sub in comps:
                    c.add(cost_of(comps[sub], comps, memo), 1.0)
            continue
        if ins.op == "fusion":
            sub = ins.attr("calls")
            if sub and sub in comps:
                inner = cost_of(comps[sub], comps, memo)
                c.flops += inner.flops        # dots inside fusions
            # fusion bytes = its operands + output (inner ops stay in regs)
            c.traffic_bytes += _io_bytes(ins, comp)
            continue
        if ins.op == "dot":
            c.flops += _dot_flops(ins, comp)
            c.traffic_bytes += _io_bytes(ins, comp)
            continue
        hit = None
        for kind in COLLECTIVE_OPS:
            if ins.op == kind or ins.op.startswith(kind + "-"):
                hit = kind
                break
        if hit:
            c.collective_bytes[hit] += _wire_bytes(ins, hit)
            c.collective_counts[hit] += 1
            c.traffic_bytes += _io_bytes(ins, comp)
            continue
        if ins.op in _FREE_OPS:
            continue
        if ins.op in _OUTPUT_ONLY_OPS:
            c.traffic_bytes += ins.result_bytes()
            continue
        if ins.op in _WINDOW_WRITE_OPS:
            ops_ = ins.operand_names()
            upd = comp.instrs.get(ops_[1].lstrip("%")) if len(ops_) > 1 else None
            c.traffic_bytes += 2.0 * (upd.result_bytes() if upd is not None
                                      else ins.result_bytes())
            continue
        c.traffic_bytes += _io_bytes(ins, comp)
    return c


def analyze_hlo(text: str, entry: str | None = None) -> Cost:
    comps = parse_hlo(text)
    if not comps:
        return Cost()
    if entry is None:
        # the entry computation is conventionally named 'main...' or is the
        # one not called by others; pick by name first
        entry_comp = None
        for name in comps:
            if name.startswith("main"):
                entry_comp = name
                break
        if entry_comp is None:
            entry_comp = next(iter(comps))
        entry = entry_comp
    memo: dict = {}
    return cost_of(comps[entry], comps, memo)
