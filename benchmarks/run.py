# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: one module per paper table/figure + the roofline.

  Fig. 3   partition_points     candidate partition point counts
  Fig. 15  latency_grid         beta vs nodes/classes/capacity
  Fig. 16  vs_random            ~10x over the random algorithm
  Fig. 17  vs_joint             vs greedy joint optimization (35% @ 50 nodes)
  Table 2  approx_ratio         approximation ratios + 5.4% optimality
  Table 3  fault_tolerance      live fault-injection matrix (both engines)
  Table 4  emulator_bench       throughput/E2E by cluster shape + fleet
                                scale; fast-engine latency vs
                                BENCH_emulator.json
  (ours)   roofline             3-term roofline per dry-run cell
  (ours)   planner_scale        planner latency vs BENCH_planner.json
  (ours)   serve_bench          serving tok/s (jitted fast path vs eager
                                loop) vs BENCH_serve.json
"""

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=None,
                    help="override per-benchmark repetitions (paper used 50)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--trials", type=int, default=200,
                    help="optimality-rate trials (paper used 1000)")
    args = ap.parse_args()

    from . import (approx_ratio, emulator_bench, fault_tolerance,
                   latency_grid, partition_points, planner_scale, roofline,
                   serve_bench, transfer_classes, vs_joint, vs_random)

    suites = {
        "planner_scale": lambda: planner_scale.run(args.reps or 3),
        "partition_points": lambda: partition_points.run(),
        "transfer_classes": lambda: transfer_classes.run(),
        "latency_grid": lambda: latency_grid.run(args.reps or 4),
        "vs_random": lambda: vs_random.run(args.reps or 8),
        "vs_joint": lambda: vs_joint.run(args.reps or 8),
        "approx_ratio": lambda: approx_ratio.run(args.reps or 10,
                                                 args.trials),
        "fault_tolerance": lambda: fault_tolerance.run(),
        "emulator_bench": lambda: emulator_bench.run(args.reps or 3),
        "serve_bench": lambda: serve_bench.run(args.reps or 3),
        "roofline": lambda: roofline.run(),
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            rows = fn()
        except Exception as e:                      # keep the suite running
            print(f"{name}/SUITE_ERROR,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            continue
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
