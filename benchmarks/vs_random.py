"""Paper Fig. 16: our algorithm vs the random algorithm (~10x average)."""

from __future__ import annotations

import numpy as np

from repro.core import (PartitionInfeasible, PlacementInfeasible,
                        partition_and_place, random_algorithm,
                        random_geometric_cluster)

from .common import FIG_MODELS, build_model, timed


def compare(graph, n_nodes, cap_mb, reps, n_classes=11, seed0=0):
    ratios, ours_list = [], []
    for r in range(reps):
        cluster = random_geometric_cluster(n_nodes, rng=seed0 + 31 * r)
        try:
            ours = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=r).bottleneck_s
            rand = np.mean([
                random_algorithm(graph, cluster, cap_mb * 1e6,
                                 rng=1000 + 17 * r + j).bottleneck_s
                for j in range(5)])
        except (PartitionInfeasible, PlacementInfeasible):
            continue
        ratios.append(rand / ours)
        ours_list.append(ours)
    return (float(np.mean(ratios)) if ratios else None,
            float(np.mean(ours_list)) if ours_list else None)


def run(reps: int = 8, node_counts=(10, 20, 50), caps=(64, 256)):
    rows = []
    all_ratios = []
    for mname in FIG_MODELS:
        g = build_model(mname)
        for n in node_counts:
            for cap in caps:
                (ratio, ours), us = timed(compare, g, n, cap, reps)
                if ratio:
                    all_ratios.append(ratio)
                rows.append({
                    "name": f"vs_random/{mname}/n{n}/cap{cap}MB",
                    "us_per_call": us / max(reps, 1),
                    "derived": round(ratio, 2) if ratio else "infeasible"})
    rows.append({"name": "vs_random/GEOMEAN_speedup", "us_per_call": 0.0,
                 "derived": round(float(np.exp(np.mean(np.log(all_ratios)))), 2)
                 if all_ratios else "n/a"})
    return rows
