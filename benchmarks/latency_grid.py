"""Paper Fig. 15: bottleneck latency vs (model, capacity, #nodes, #classes).

Validates the paper's trends: beta falls as nodes / classes / capacity
grow; small-capacity small-cluster cells go infeasible (the blank cells of
Fig. 15).
"""

from __future__ import annotations

import numpy as np

from repro.core import (PartitionInfeasible, PlacementInfeasible,
                        partition_and_place, random_geometric_cluster)

from .common import CAPACITIES_MB, CLASS_COUNTS, NODE_COUNTS, build_model, timed


def cell(graph, n_nodes, n_classes, cap_mb, reps, seed0=0):
    betas = []
    for r in range(reps):
        cluster = random_geometric_cluster(n_nodes, rng=seed0 + 7919 * r)
        try:
            plan = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=seed0 + r)
            betas.append(plan.bottleneck_s)
        except (PartitionInfeasible, PlacementInfeasible):
            continue
    return float(np.mean(betas)) if betas else None


def run(reps: int = 4, models=("ResNet50", "InceptionResNetV2"),
        node_counts=(5, 20, 50), class_counts=(2, 11, 20),
        caps=(64, 128, 256)):
    rows = []
    for mname in models:
        g = build_model(mname)
        for cap in caps:
            for n in node_counts:
                for nc in class_counts:
                    (beta), us = timed(cell, g, n, nc, cap, reps)
                    rows.append({
                        "name": f"latency_grid/{mname}/cap{cap}MB/n{n}/c{nc}",
                        "us_per_call": us / max(reps, 1),
                        "derived": round(beta, 4) if beta else "infeasible"})
    return rows


def trend_check(reps: int = 6):
    """Assertable trends for tests: more nodes and classes help."""
    g = build_model("InceptionResNetV2")
    small = cell(g, 10, 2, 64, reps, seed0=3)
    big = cell(g, 50, 20, 64, reps, seed0=3)
    return small, big
