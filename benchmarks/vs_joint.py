"""Paper Fig. 17: k-path matching vs greedy joint optimization.

Paper's finding: joint wins at small node counts; k-path matching wins as
the cluster grows (35% at 50 nodes).
"""

from __future__ import annotations

import numpy as np

from repro.core import (PartitionInfeasible, PlacementInfeasible,
                        joint_greedy, partition_and_place,
                        random_geometric_cluster)

from .common import FIG_MODELS, build_model, timed


def compare(graph, n_nodes, cap_mb, reps, n_classes=11, seed0=0):
    improvements = []
    for r in range(reps):
        cluster = random_geometric_cluster(n_nodes, rng=seed0 + 101 * r)
        try:
            ours = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=r).bottleneck_s
            joint = joint_greedy(graph, cluster, cap_mb * 1e6).bottleneck_s
        except (PartitionInfeasible, PlacementInfeasible):
            continue
        improvements.append((joint - ours) / joint)     # + => we win
    return float(np.mean(improvements)) if improvements else None


def run(reps: int = 8, node_counts=(5, 10, 20, 50), caps=(64, 256)):
    rows = []
    at50 = []
    for mname in FIG_MODELS:
        g = build_model(mname)
        for n in node_counts:
            for cap in caps:
                imp, us = timed(compare, g, n, cap, reps)
                if imp is not None and n == 50:
                    at50.append(imp)
                rows.append({
                    "name": f"vs_joint/{mname}/n{n}/cap{cap}MB",
                    "us_per_call": us / max(reps, 1),
                    "derived": f"{imp * 100:+.1f}%" if imp is not None
                    else "infeasible"})
    rows.append({"name": "vs_joint/MEAN_improvement_at_50_nodes",
                 "us_per_call": 0.0,
                 "derived": f"{np.mean(at50) * 100:+.1f}%" if at50 else "n/a"})
    return rows
