"""Roofline assembly: read results/dryrun.json, emit the per-cell 3-term
table (compute / memory / collective seconds), dominant bottleneck,
MODEL_FLOPS ratio, and roofline fractions.

Hardware constants (TPU v5e per brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import get_config
from repro.models.config import SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N*D prefill, 2*N*B decode
    (N = active params for MoE)."""
    cfg = get_config(arch, "full")
    shp = SHAPES[shape_name]
    n = cfg.param_count(active_only=bool(cfg.n_experts))
    d = shp.tokens_per_step
    mult = 6.0 if shp.kind == "train" else 2.0
    return mult * n * d


def analyze_record(rec: dict) -> dict | None:
    if rec.get("skipped") or not rec.get("ok") or "walker" not in rec:
        return None
    w = rec["walker"]
    chips = CHIPS[rec["mesh"]]
    compute_s = w["flops_per_device"] / PEAK_FLOPS
    memory_s = w["traffic_bytes_per_device"] / HBM_BW
    coll_s = w["collective_total_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())              # perfect-overlap bound
    mf = model_flops(rec["arch"], rec["shape"]) / chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "hlo_flops_per_chip": w["flops_per_device"],
        "useful_ratio": mf / w["flops_per_device"]
        if w["flops_per_device"] else 0.0,
        # roofline fraction: useful-model-compute time / bound step time
        "roofline_frac": (mf / PEAK_FLOPS) / step_s if step_s else 0.0,
        "memory_gb": (rec.get("memory", {}).get("temp_bytes") or 0) / 1e9,
        "collective_breakdown": w["collective_wire_bytes"],
    }


def load(results_path=RESULTS) -> list[dict]:
    recs = json.loads(Path(results_path).read_text())
    out = []
    for r in recs:
        a = analyze_record(r)
        if a:
            out.append(a)
    return out


def markdown_table(rows, mesh="single") -> str:
    hdr = ("| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | 6ND/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac'] * 100:.1f}% |")
    return "\n".join(lines)


def run(reps: int = 1):
    rows = load()
    out = []
    for r in rows:
        if r["mesh"] != "single":
            continue
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": 0.0,
            "derived": (f"dom={r['dominant']} frac="
                        f"{r['roofline_frac'] * 100:.1f}%")})
    return out


if __name__ == "__main__":
    rows = load()
    print(markdown_table(rows, "single"))
