"""Paper Table 2 + §6.1 optimality study.

  * approximation ratio beta / Theorem-1 bound for k-path matching vs the
    joint-greedy baseline at 16/32/64 MB (Table 2),
  * the fraction of runs hitting the Theorem-1 optimum exactly
    (paper: 5.4% for InceptionResNetV2, 50 nodes, 64 MB, 20 classes),
  * beyond-paper: ratio vs the *exact* optimum (subset-DP) on 12-node
    clusters, where Theorem 1 is only a lower bound.
"""

from __future__ import annotations

import numpy as np

from repro.core import (PartitionInfeasible, PlacementInfeasible,
                        exact_optimal_bottleneck, joint_greedy,
                        partition_and_place, random_geometric_cluster,
                        theorem1_bound)

from .common import build_model, timed


def ratios(graph, cap_mb, reps, n_nodes=20, n_classes=11, seed0=0):
    ours_r, joint_r = [], []
    for r in range(reps):
        cluster = random_geometric_cluster(n_nodes, rng=seed0 + 13 * r)
        try:
            plan = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=r)
            thm = plan.evaluation.theorem1_s
            ours_r.append(plan.bottleneck_s / thm)
            jg = joint_greedy(graph, cluster, cap_mb * 1e6)
            joint_r.append(jg.bottleneck_s /
                           theorem1_bound(jg.sizes, cluster))
        except (PartitionInfeasible, PlacementInfeasible):
            continue
    return (float(np.mean(ours_r)) if ours_r else None,
            float(np.mean(joint_r)) if joint_r else None)


def optimality_rate(graph, trials=200, n_nodes=50, cap_mb=64, n_classes=20,
                    tol=1e-9):
    """Fraction of runs whose beta is within ``tol`` of the Theorem-1 bound.

    Note on granularity: our DAGs cut at block boundaries, so the max
    transfer size is often *repeated* across adjacent boundaries — the
    Theorem-1 bound (which assumes the single max rides the single best
    edge) is then strictly unreachable; the paper's layer-level cuts give
    unique maxima.  We therefore report exact and near-hit rates."""
    hits = 0
    done = 0
    for r in range(trials):
        cluster = random_geometric_cluster(n_nodes, rng=5000 + r)
        try:
            plan = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=r)
        except (PartitionInfeasible, PlacementInfeasible):
            continue
        done += 1
        if plan.bottleneck_s <= plan.evaluation.theorem1_s * (1 + tol):
            hits += 1
    return hits / max(done, 1), done


def exact_audit(graph, cap_mb=64, reps=6, n_nodes=12, n_classes=5):
    """beyond-paper: vs the true optimum on small clusters."""
    rs = []
    for r in range(reps):
        cluster = random_geometric_cluster(n_nodes, rng=9000 + r)
        try:
            plan = partition_and_place(graph, cluster, cap_mb * 1e6,
                                       n_classes=n_classes, rng=r)
            opt = exact_optimal_bottleneck(plan.partition.boundary_sizes,
                                           cluster)
            rs.append(plan.bottleneck_s / opt)
        except (PartitionInfeasible, PlacementInfeasible):
            continue
    return float(np.mean(rs)) if rs else None


def run(reps: int = 10, trials: int = 200):
    rows = []
    models = {"ResNet50": build_model("ResNet50"),
              "MobileNetV2": build_model("MobileNetV2"),
              "InceptionResNetV2": build_model("InceptionResNetV2")}
    for cap in (16, 32, 64):
        ours_all, joint_all = [], []
        for mname, g in models.items():
            o, j = ratios(g, cap, reps)
            if o:
                ours_all.append(o)
            if j:
                joint_all.append(j)
        rows.append({"name": f"approx_ratio/kpath/cap{cap}MB",
                     "us_per_call": 0.0,
                     "derived": round(float(np.mean(ours_all)), 3)
                     if ours_all else "infeasible"})
        rows.append({"name": f"approx_ratio/joint/cap{cap}MB",
                     "us_per_call": 0.0,
                     "derived": round(float(np.mean(joint_all)), 3)
                     if joint_all else "infeasible"})
    for tol, label in ((1e-9, "exact"), (0.005, "within0.5%"),
                       (0.02, "within2%")):
        (rate, done), us = timed(optimality_rate,
                                 models["InceptionResNetV2"], trials,
                                 tol=tol)
        rows.append({"name": f"optimality_rate/{label}/IRNv2/50n/64MB/20c "
                             f"({done} runs)",
                     "us_per_call": us / max(done, 1),
                     "derived": f"{rate * 100:.1f}%"})
    ex, us2 = timed(exact_audit, models["ResNet50"])
    rows.append({"name": "exact_audit/ResNet50/12n (beyond-paper)",
                 "us_per_call": us2 / 6,
                 "derived": round(ex, 3) if ex else "n/a"})
    return rows
