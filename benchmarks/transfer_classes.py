"""Paper Fig. 12 + §5.3: transfer-size class counts (Doane's estimator)
and the analytic RGG statistics the paper derives.

Fig. 12: the number of histogram bins needed to represent each model's
candidate-point transfer sizes (paper: most models need ~11, almost all in
11-13).  §5.3.1: E[r] ~ 4.766 Mbps, sigma ~ 1.398, CV ~ 0.293 over the
annulus-square uniform node placement.  §5.3.2: RGG clustering coefficient
C ~ 0.587 and full connectivity of the high-bandwidth subgraph.
"""

from __future__ import annotations

import numpy as np

from repro.configs.paper_cnns import PAPER_MODELS
from repro.core import (DEFAULT_COMPRESSION, shannon_bandwidth_mbps,
                        random_geometric_cluster, MBPS)
from repro.core.partitioner import transfer_sizes

from .common import timed


def doane_bins(x) -> int:
    """Doane's estimator for histogram bin count."""
    x = np.asarray(x, dtype=float)
    n = len(x)
    if n < 3 or np.std(x) == 0:
        return 1
    g1 = float(np.mean(((x - x.mean()) / x.std()) ** 3))
    sg1 = np.sqrt(6.0 * (n - 2) / ((n + 1) * (n + 3)))
    return int(1 + np.log2(n) + np.log2(1 + abs(g1) / sg1))


def model_bins():
    rows = []
    for name, fn in PAPER_MODELS.items():
        g = fn()
        pts = g.candidate_partition_points()
        segs = g.segment_layers(pts)
        ts = transfer_sizes(g, pts, segs, DEFAULT_COMPRESSION)
        rows.append((name, doane_bins(ts)))
    return rows


def rgg_stats(n_samples: int = 200_000, seed: int = 0):
    """Monte-Carlo check of Eq. 18: mean/std/CV of r(x, y) over the paper's
    uniform annulus-square placement."""
    rng = np.random.default_rng(seed)
    b = 150.0
    mag = rng.uniform(1.0, b, size=(n_samples, 2))
    sign = rng.choice([-1.0, 1.0], size=(n_samples, 2))
    pos = mag * sign
    r = shannon_bandwidth_mbps(np.linalg.norm(pos, axis=1))
    return float(r.mean()), float(r.std()), float(r.std() / r.mean())


def high_class_connectivity(trials: int = 20, n: int = 50):
    """§5.3.2: the subgraph of above-average-bandwidth edges stays one
    connected component (P(alpha)=1), enabling k-paths.  The paper models
    this as a standard RGG — bandwidth from inter-node distance (Eq. 13),
    H-class edges are those within ~104 m (D(x) >= mu)."""
    connected = 0
    for t in range(trials):
        c = random_geometric_cluster(n, rng=t, edge_model="distance")
        thr = shannon_bandwidth_mbps(103.944) * MBPS   # D(x) = mu (Eq. 19)
        adj = c.bw >= thr
        # BFS from node 0 over the H-class subgraph
        seen = {0}
        stack = [0]
        while stack:
            u = stack.pop()
            for v in np.flatnonzero(adj[u]):
                if v not in seen:
                    seen.add(int(v))
                    stack.append(int(v))
        connected += (len(seen) == n)
    return connected / trials


def run(reps: int = 1):
    rows = []
    for name, bins in model_bins():
        rows.append({"name": f"transfer_classes/{name}", "us_per_call": 0.0,
                     "derived": bins})
    (mu, sigma, cv), us = timed(rgg_stats)
    rows.append({"name": "rgg_stats/mean_mbps (paper 4.766)",
                 "us_per_call": us, "derived": round(mu, 3)})
    rows.append({"name": "rgg_stats/std_mbps (paper 1.398)",
                 "us_per_call": 0.0, "derived": round(sigma, 3)})
    rows.append({"name": "rgg_stats/cv (paper 0.293)",
                 "us_per_call": 0.0, "derived": round(cv, 3)})
    frac, us2 = timed(high_class_connectivity)
    rows.append({"name": "rgg_stats/H_subgraph_connected (paper P=1)",
                 "us_per_call": us2, "derived": f"{frac * 100:.0f}%"})
    return rows
