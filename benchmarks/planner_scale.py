"""Tracked planner-latency benchmark (BENCH_planner.json).

Times the two planner hot paths at production scale:

  * ``optimal_partitions`` (Algorithm 1) on the paper's biggest CNN DAGs and
    the large-LLM block graphs (llama3-405b: 129 candidate points,
    deepseek-v3-671b), cold-cache per rep, against the naive
    O(K^2 * L) reference (the pre-index implementation, kept inline here);
  * end-to-end ``partition_and_place`` across the paper grid (5-50 nodes)
    against the unpruned threshold search + naive DP.

Usage:
  python -m benchmarks.planner_scale --update [--reps N]  # re-measure + write
  python -m benchmarks.planner_scale --check  [--reps N]  # CI: fail on >2x
  python -m benchmarks.planner_scale                      # print, no write

``--check`` re-times the optimized paths only and fails when any entry's
median exceeds CHECK_RATIO x the committed median (ratio-of-medians, so
machine noise on one rep doesn't trip it).  ``--update`` is the only mode
that runs the (slow) naive baselines; run it when the planner changes and
commit the refreshed BENCH_planner.json alongside.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from contextlib import contextmanager

from repro.configs import get_config
from repro.configs.paper_cnns import PAPER_MODELS
from repro.core import partition_and_place, random_geometric_cluster
from repro.core.equivalence import stage_budget_bytes
from repro.core.partitioner import (NotPartitionable, PartitionInfeasible,
                                    optimal_partitions)
from repro.core.pipeline import lm_block_graph
from repro.models.config import SHAPES

from .common import check_bench, load_bench, time_us

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_planner.json")
CHECK_RATIO = 2.0           # --check fails on >2x regression vs committed
DEFAULT_REPS = 5

# Algorithm-1 cases: (name, graph factory, capacity bytes, lambda)
def _cnn(name):
    return PAPER_MODELS[name]()


def _llm(arch, shape="prefill_32k"):
    cfg = get_config(arch, "full")
    return lm_block_graph(cfg, SHAPES[shape])


def _llm_cap(arch, shape="prefill_32k", frac=0.25, floor=1.35):
    cfg = get_config(arch, "full")
    return stage_budget_bytes(cfg, SHAPES[shape], frac, floor)


def partition_cases():
    from repro.core.bottleneck import DEFAULT_COMPRESSION
    return [
        ("ResNet50", lambda: _cnn("ResNet50"), 30e6, DEFAULT_COMPRESSION),
        ("InceptionResNetV2", lambda: _cnn("InceptionResNetV2"), 30e6,
         DEFAULT_COMPRESSION),
        ("BERT-Large", lambda: _cnn("BERT-Large"), 200e6, DEFAULT_COMPRESSION),
        ("llama3-405b", lambda: _llm("llama3-405b"),
         _llm_cap("llama3-405b", floor=1.6), 2.0),
        ("deepseek-v3-671b", lambda: _llm("deepseek-v3-671b"),
         _llm_cap("deepseek-v3-671b"), 2.0),
    ]


# End-to-end cases: (name, model, cap bytes, nodes) on the paper grid
def e2e_cases():
    cases = [(f"InceptionResNetV2/n{n}", "InceptionResNetV2", 30e6, n)
             for n in (10, 15, 20, 50)]     # 9 runs need 10 nodes minimum
    cases.append(("ResNet50/n50", "ResNet50", 30e6, 50))
    return cases


# ---------------------------------------------------------------------------
# naive baselines (pre-optimization behavior, timed by --update only)
# ---------------------------------------------------------------------------

def _optimal_partitions_naive(graph, capacity_bytes, lam, points=None):
    """The pre-index Algorithm 1: every DP cell rescans its layers.  Returns
    a full PartitionPlan (like the optimized function) so the end-to-end
    naive baseline pays exactly the pre-PR cost — nothing optimized."""
    from repro.core.partitioner import PartitionPlan
    if points is None:
        points = graph.candidate_partition_points()
    if len(points) < 2:
        raise NotPartitionable("no interior candidate points")
    segs = graph.segment_layers(points)
    tsizes = [(graph.layers[p].out_bytes + graph.boundary_side_bytes(segs, c))
              / lam for c, p in enumerate(points)]
    k = len(points)
    inf = float("inf")
    best = [inf] * (k + 1)
    choice = [-1] * k
    best[k] = 0.0
    for i in range(k - 1, -1, -1):
        for j in range(i, k):
            if graph.run_memory_bytes(points, segs, i, j) >= capacity_bytes:
                break
            cand = (0.0 if j == k - 1 else tsizes[j]) + best[j + 1]
            if cand < best[i]:
                best[i], choice[i] = cand, j
    if best[0] == inf:
        raise PartitionInfeasible("no feasible segmentation")
    runs, i = [], 0
    while i < k:
        runs.append((i, choice[i]))
        i = choice[i] + 1
    boundary = [graph.layers[points[0]].out_bytes / lam]
    for (i, j) in runs[:-1]:
        boundary.append(tsizes[j])
    part_layers = [sum((segs[s] for s in range(i, j + 1)), [])
                   for (i, j) in runs]
    mems = [graph.run_memory_bytes(points, segs, i, j) for (i, j) in runs]
    flops = [sum(graph.layers[nm].flops for nm in names)
             for names in part_layers]
    return PartitionPlan(points=points, runs=runs, boundary_sizes=boundary,
                         partition_layers=part_layers, memory_bytes=mems,
                         candidate_sizes=tsizes, compute_flops=flops,
                         total_cost=best[0])


@contextmanager
def naive_planner():
    """Swap in the unpruned threshold search and the naive DP so
    partition_and_place exhibits its pre-optimization latency."""
    from repro.core import api, placement

    saved = (placement.subgraph_k_path, api.optimal_partitions)
    placement.subgraph_k_path = placement.subgraph_k_path_reference
    api.optimal_partitions = _optimal_partitions_naive
    try:
        yield
    finally:
        placement.subgraph_k_path, api.optimal_partitions = saved


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def measure(reps: int, with_naive: bool) -> dict:
    """Methodology: per rep the accounting index cache is cleared (its build
    cost is part of the optimized number) while the graph-structure caches
    (topo order / depths / candidate points) stay warm for BOTH the
    optimized and naive paths — that is the production steady state
    (replanning the same model), it is shared fairly by both sides, and
    keeping it out of the ratio makes the reported speedups conservative."""
    entries: dict[str, dict] = {}
    for name, build, cap, lam in partition_cases():
        g = build()

        def run_opt():
            g._acc_cache.clear()            # cold index: count its build cost
            optimal_partitions(g, cap, lam)

        med, lo = time_us(run_opt, reps)
        e = {"median_us": med, "min_us": lo}
        if with_naive:
            e["naive_median_us"], _ = time_us(
                lambda: _optimal_partitions_naive(g, cap, lam), reps)
            e["speedup"] = round(e["naive_median_us"] / e["median_us"], 2)
            # sanity: same plan either way
            ref = _optimal_partitions_naive(g, cap, lam)
            plan = optimal_partitions(g, cap, lam)
            assert plan.runs == ref.runs and plan.total_cost == ref.total_cost
        entries[f"optimal_partitions/{name}"] = e

    for name, model, cap, n in e2e_cases():
        g = PAPER_MODELS[model]()
        cluster = random_geometric_cluster(n, rng=n)

        def run_opt():
            g._acc_cache.clear()
            return partition_and_place(g, cluster, cap, n_classes=3, rng=0)

        med, lo = time_us(run_opt, reps)
        e = {"median_us": med, "min_us": lo}
        if with_naive:
            def run_naive():
                g._acc_cache.clear()
                with naive_planner():
                    return partition_and_place(g, cluster, cap,
                                               n_classes=3, rng=0)
            e["naive_median_us"], _ = time_us(run_naive, reps)
            e["speedup"] = round(e["naive_median_us"] / e["median_us"], 2)
            a, b = run_opt(), run_naive()
            assert (a.partition.runs == b.partition.runs
                    and a.placement.nodes == b.placement.nodes
                    and a.bottleneck_s == b.bottleneck_s)
        entries[f"partition_and_place/{name}"] = e
    return entries


def check(reps: int) -> int:
    return check_bench("planner_scale", BENCH_PATH,
                       measure(reps, with_naive=False), CHECK_RATIO)


def update(reps: int) -> None:
    entries = measure(reps, with_naive=True)
    doc = {
        "meta": {
            "reps": reps,
            "tool": "benchmarks/planner_scale.py --update",
            "note": ("median microseconds per call; naive = pre-index DP + "
                     "unpruned threshold search; --check compares medians "
                     f"with a {CHECK_RATIO}x ratio tolerance"),
        },
        "entries": entries,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, e in sorted(entries.items()):
        print(f"{name}: {e['median_us']:.0f}us "
              f"(naive {e['naive_median_us']:.0f}us, x{e['speedup']})")


def run(reps: int = 3):
    """benchmarks.run entry point: optimized timings + committed speedups."""
    committed = load_bench(BENCH_PATH) or {"entries": {}}
    rows = []
    for name, e in measure(reps, with_naive=False).items():
        derived = committed["entries"].get(name, {}).get("speedup", "")
        rows.append({"name": f"planner_scale/{name}",
                     "us_per_call": e["median_us"],
                     "derived": f"committed_speedup={derived}"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="measure optimized + naive, write BENCH_planner.json")
    ap.add_argument("--check", action="store_true",
                    help=f"fail on >{CHECK_RATIO}x regression vs committed")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    reps = args.reps or (DEFAULT_REPS if (args.update or args.check) else 3)
    if args.update:
        update(reps)
    elif args.check:
        sys.exit(check(reps))
    else:
        for r in run(reps):
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
