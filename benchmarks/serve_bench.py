"""Tracked serving benchmark (BENCH_serve.json).

Times the repro.serve fast path against the eager reference loop on the
smoke presets (real JAX compute on CPU):

* ``prefill/<arch>`` — one jitted prefill (cache allocation included);
* ``decode/<arch>``  — steady-state greedy decode: prefill outside the
  clock, DECODE_STEPS jitted steps timed, block_until_ready before the
  clock stops.  ``naive`` is the eager per-token loop — the >= 5x
  speedup here is the tentpole acceptance number;
* ``stream/<arch>``  — a staggered request stream through the slot
  scheduler (continuous batching) vs serving the same requests one at a
  time with the eager loop;
* ``pipeline_decode[_int8]/<arch>`` — steady-state decode through
  ``PipelineServeEngine`` over a mid-model stage cut (the stage IR), with
  a raw and a rowwise-int8-quantized boundary wire; ``vs_monolithic`` is
  the decode throughput ratio vs the monolithic fast path — monolithic
  median / pipelined median, bigger = better, < 1 means the partition
  costs throughput (raw wire asserts token identity live; int8 is lossy
  by design);
* ``pipeline_decode_4stage[_overlap]/<arch>`` — the same decode over a
  4-stage cut, sequential vs the overlapped executor (``overlap=True``:
  async dispatch, donated boundary buffers, micro-batch interleave), an
  on/off ablation so the overlap win is attributable; ``--check``
  additionally gates the tentpole acceptance number: overlapped 4-stage
  decode at >= 1.0x monolithic throughput (best-of-reps);
* ``wire_faults/<arch>`` — the same pipelined decode with every boundary
  handoff framed through ``BoundaryTransport`` under a seeded wire-fault
  schedule (rate ``WIRE_LOSS``): ``wire_overhead`` is the framing +
  retransmit cost vs the transportless pipe, and the committed median is
  the bound ``--check`` enforces; ``--update`` asserts token identity and
  exactly-once delivery live.

Every ``--update`` run asserts the fast path token-identical to the
reference on the exact cases it times (the equivalence contract, live).

Usage:
  python -m benchmarks.serve_bench --update [--reps N]  # re-measure + write
  python -m benchmarks.serve_bench --check  [--reps N]  # CI: fail on >2x
  python -m benchmarks.serve_bench                      # print, no write

``--check`` re-times the fast path only and fails when any entry's
best-of-reps exceeds CHECK_RATIO x the committed median (same methodology
as planner_scale.py / emulator_bench.py; regenerate on a uniformly slower
host rather than chasing phantom regressions).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine, SlotScheduler
from repro.serve.equivalence import make_batch

from .common import check_bench, time_s

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
CHECK_RATIO = 2.0           # --check fails on >2x regression vs committed
DEFAULT_REPS = 5

# one arch per headline family; MoE is benchmarked (throughput) but its
# stream tokens are not asserted (batch-coupled expert capacity — see
# repro.serve.scheduler)
ARCHES = ["granite-3-2b", "mamba2-1.3b", "llama4-maverick-400b-a17b"]
BATCH, PROMPT_LEN, DECODE_STEPS = 4, 32, 32
MAX_LEN, KV_BLOCK = 96, 32

STREAM_ARCH = "granite-3-2b"
PIPE_ARCH = "granite-3-2b"          # pipelined decode: mid-model stage cut
WIRE_LOSS = 0.15                    # wire_faults/ seeded fault rate
WIRE_SEED = 4                       # draws all five fault kinds at this rate
STREAM_SLOTS = 4
# (prompt_len, gen_len) per request — staggered completions force
# admit/evict churn rather than one synchronized batch
STREAM_REQS = [(32, 24), (32, 12), (16, 20), (32, 8), (16, 28), (32, 16),
               (16, 12), (32, 20), (16, 24), (32, 10), (16, 16), (32, 24)]


def _engine(arch: str) -> ServeEngine:
    cfg = get_config(arch, "smoke")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_len=MAX_LEN, kv_block=KV_BLOCK)


def _stream_requests(cfg):
    return [Request(rid=i,
                    tokens=np.asarray(make_batch(cfg, 1, p, 300 + i)
                                      ["tokens"]),
                    gen_len=g)
            for i, (p, g) in enumerate(STREAM_REQS)]


def measure(reps: int, with_naive: bool) -> dict:
    entries: dict[str, dict] = {}

    for arch in ARCHES:
        eng = _engine(arch)
        batch = make_batch(eng.cfg, BATCH, PROMPT_LEN, 42)
        eng.warmup(batch, DECODE_STEPS + 1)           # compile off the clock

        med, lo = time_s(lambda: eng.timed_prefill(batch), reps)
        e = {"median_us": med * 1e6, "min_us": lo * 1e6}
        if with_naive:
            nmed, _ = time_s(
                lambda: eng.timed_prefill(batch, engine="reference"), reps)
            e["naive_median_us"] = nmed * 1e6
            e["speedup"] = round(nmed / med, 2)
        entries[f"prefill/{arch}"] = e

        toks = DECODE_STEPS * BATCH
        med, lo = time_s(lambda: eng.timed_decode(batch, DECODE_STEPS), reps)
        e = {"median_us": med * 1e6, "min_us": lo * 1e6,
             "decode_toks_per_s": round(toks / med, 1)}
        if with_naive:
            nmed, _ = time_s(
                lambda: eng.timed_decode(batch, DECODE_STEPS,
                                         engine="reference"),
                max(1, reps // 2))
            e["naive_median_us"] = nmed * 1e6
            e["naive_toks_per_s"] = round(toks / nmed, 1)
            e["speedup"] = round(nmed / med, 2)
            # equivalence contract, live: same tokens from both paths
            ref = eng.generate(batch, DECODE_STEPS, engine="reference")
            fast = eng.generate(batch, DECODE_STEPS, engine="fast")
            assert (ref == fast).all(), \
                f"{arch}: fast path diverged from reference tokens"
        entries[f"decode/{arch}"] = e

    # -- pipelined serving over the stage IR --------------------------------
    from repro.core.stageplan import from_block_cuts
    from repro.serve import PipelineServeEngine

    eng = _engine(PIPE_ARCH)
    batch = make_batch(eng.cfg, BATCH, PROMPT_LEN, 42)
    eng.warmup(batch, DECODE_STEPS + 1)
    mono_med, _ = time_s(lambda: eng.timed_decode(batch, DECODE_STEPS), reps)
    toks = DECODE_STEPS * BATCH
    for name, bits in [("pipeline_decode", 0), ("pipeline_decode_int8", 8)]:
        plan = from_block_cuts(eng.cfg, [eng.cfg.n_layers // 2],
                               wire_bits=bits)
        peng = PipelineServeEngine(eng.cfg, eng.params, plan,
                                   max_len=MAX_LEN, kv_block=KV_BLOCK)
        peng.warmup(batch, DECODE_STEPS + 1)
        med, lo = time_s(lambda: peng.timed_decode(batch, DECODE_STEPS),
                         reps)
        e = {"median_us": med * 1e6, "min_us": lo * 1e6,
             "decode_toks_per_s": round(toks / med, 1),
             "mono_median_us": mono_med * 1e6,
             "vs_monolithic": round(mono_med / med, 2), "wire_bits": bits}
        if with_naive and bits == 0:
            # equivalence contract, live: pipelined == monolithic tokens
            mono = eng.generate(batch, DECODE_STEPS, engine="fast")
            pipe = peng.generate(batch, DECODE_STEPS)
            assert (mono == pipe).all(), \
                f"{PIPE_ARCH}: pipelined tokens diverged from monolithic"
        entries[f"{name}/{PIPE_ARCH}"] = e

    # -- 4-stage cut: sequential vs overlapped executor (ablation) ----------
    # The smoke preset is deepened to 4 layers so the plan has interior
    # cuts (same recipe as the equivalence cells).  Both cells serve the
    # identical model/batch as their own 4-layer monolithic baseline, so
    # vs_monolithic is comparable across the on/off pair and the overlap
    # win is attributable to the executor alone (on one shared device the
    # overlapped executor degenerates to a single fused dispatch per
    # micro-batch — the boundary handoff never materializes; see
    # PipelineServeEngine._fused_ok).
    cfg4 = get_config(PIPE_ARCH, "smoke")
    if cfg4.n_layers < 4:
        cfg4 = cfg4.replace(n_layers=4)
    params4 = init_params(cfg4, jax.random.PRNGKey(0))
    eng4 = ServeEngine(cfg4, params4, max_len=MAX_LEN, kv_block=KV_BLOCK)
    batch4 = make_batch(cfg4, BATCH, PROMPT_LEN, 42)
    eng4.warmup(batch4, DECODE_STEPS + 1)
    mono4_med, mono4_lo = time_s(
        lambda: eng4.timed_decode(batch4, DECODE_STEPS), reps)
    mono4_toks = eng4.generate(batch4, DECODE_STEPS, engine="fast") \
        if with_naive else None
    toks4 = DECODE_STEPS * BATCH
    plan4 = from_block_cuts(cfg4, [1, 2, 3])
    for name, ov in [("pipeline_decode_4stage", False),
                     ("pipeline_decode_4stage_overlap", True)]:
        peng = PipelineServeEngine(cfg4, params4, plan4, max_len=MAX_LEN,
                                   kv_block=KV_BLOCK, overlap=ov)
        peng.warmup(batch4, DECODE_STEPS + 1)
        med, lo = time_s(lambda: peng.timed_decode(batch4, DECODE_STEPS),
                         reps)
        e = {"median_us": med * 1e6, "min_us": lo * 1e6,
             "decode_toks_per_s": round(toks4 / med, 1),
             "mono_median_us": mono4_med * 1e6,
             "mono_min_us": mono4_lo * 1e6,
             "vs_monolithic": round(mono4_med / med, 2),
             "overlap": ov,
             "micro_batches": peng._resolve_micro(BATCH)}
        if with_naive:
            # equivalence contract, live: the overlapped executor reorders
            # execution, never math — same tokens as the monolithic engine
            pipe = peng.generate(batch4, DECODE_STEPS)
            assert (mono4_toks == pipe).all(), \
                f"{name}: pipelined tokens diverged from monolithic"
        entries[f"{name}/{PIPE_ARCH}"] = e

    # -- pipelined decode over an unreliable wire ---------------------------
    # the framed BoundaryTransport under a seeded fault schedule at a fixed
    # loss rate: the committed median (gated by --check's ratio tolerance)
    # bounds the retransmit + framing overhead vs the transportless pipe
    from repro.serve.retry import RetryPolicy
    from repro.serve.transport import (BoundaryTransport, FakeWireClock,
                                       HeartbeatMonitor, seeded_wire_faults)

    plan = from_block_cuts(eng.cfg, [eng.cfg.n_layers // 2])
    peng = PipelineServeEngine(eng.cfg, eng.params, plan,
                               max_len=MAX_LEN, kv_block=KV_BLOCK)
    peng.warmup(batch, DECODE_STEPS + 1)
    clean_med, _ = time_s(lambda: peng.timed_decode(batch, DECODE_STEPS),
                          reps)

    def _wire():
        clk = FakeWireClock()
        mon = HeartbeatMonitor(peng.n_stages, clock=clk, sleep=clk.sleep)
        peng.attach_wire(BoundaryTransport(
            peng.n_stages - 1,
            faults=seeded_wire_faults(WIRE_SEED, peng.n_stages - 1,
                                      DECODE_STEPS + 2, rate=WIRE_LOSS),
            policy=RetryPolicy(attempts=6, base_delay_s=0.0),
            monitor=mon, clock=clk, sleep=clk.sleep), mon)

    def wired_decode():
        _wire()              # fresh schedule per rep: faults fire every run
        return peng.timed_decode(batch, DECODE_STEPS)

    med, lo = time_s(wired_decode, reps)
    tr = peng.transport
    assert tr.total("retransmits") > 0, \
        f"{PIPE_ARCH}: wire_faults schedule exercised no retransmission"
    e = {"median_us": med * 1e6, "min_us": lo * 1e6,
         "decode_toks_per_s": round(toks / med, 1),
         "clean_median_us": clean_med * 1e6,
         "wire_overhead": round(med / clean_med, 2),
         "loss_rate": WIRE_LOSS,
         "retransmits": tr.total("retransmits")}
    if with_naive:
        # live contract: faulted wire delivers exactly once and the
        # greedy tokens match the transportless pipeline bit-exactly
        peng.attach_wire()
        clean_toks = peng.generate(batch, DECODE_STEPS)
        _wire()
        wired_toks = peng.generate(batch, DECODE_STEPS)
        assert (clean_toks == wired_toks).all(), \
            f"{PIPE_ARCH}: wire faults flipped greedy tokens"
        assert peng.transport.exactly_once(), \
            f"{PIPE_ARCH}: transport lost or double-delivered a frame"
    peng.attach_wire()
    entries[f"wire_faults/{PIPE_ARCH}"] = e

    # -- mixed request stream (continuous batching) -------------------------
    eng = _engine(STREAM_ARCH)
    sched = SlotScheduler(eng, slots=STREAM_SLOTS)
    reqs = _stream_requests(eng.cfg)
    total_toks = sum(g for _, g in STREAM_REQS)
    sched.run(reqs, engine="fast")                    # compile off the clock

    def fast_stream():
        _, stats = sched.run(reqs, engine="fast")
        return stats["wall_s"]

    med, lo = time_s(fast_stream, reps)
    _, stats = sched.run(reqs, engine="fast")
    e = {"median_us": med * 1e6, "min_us": lo * 1e6,
         "stream_toks_per_s": round(total_toks / med, 1),
         "slot_utilization": round(stats["slot_utilization"], 3)}
    if with_naive:
        t0 = time.perf_counter()
        ref_streams, _ = sched.run(reqs, engine="reference")
        nsec = time.perf_counter() - t0
        e["naive_median_us"] = nsec * 1e6
        e["naive_toks_per_s"] = round(total_toks / nsec, 1)
        e["speedup"] = round(nsec / med, 2)
        fast_streams, _ = sched.run(reqs, engine="fast")
        for a, b in zip(ref_streams, fast_streams):
            assert (a == b).all(), "stream tokens diverged from reference"
    entries[f"stream/{STREAM_ARCH}"] = e
    return entries


def check(reps: int) -> int:
    entries = measure(reps, with_naive=False)
    rc = check_bench("serve_bench", BENCH_PATH, entries, CHECK_RATIO)
    # tentpole acceptance gate (ISSUE 10 / ROADMAP open item 2): the
    # overlapped 4-stage pipelined decode must reach at least parity with
    # the monolithic engine on the gate model (best-of-reps on both
    # sides, the least-noise estimator --check already uses)
    ov = entries.get(f"pipeline_decode_4stage_overlap/{PIPE_ARCH}")
    if ov is not None:
        ratio = ov["mono_min_us"] / ov["min_us"]
        ok = ratio >= 1.0
        print(f"serve_bench: overlap gate {'ok' if ok else 'FAIL'} — "
              f"overlapped 4-stage decode {ratio:.2f}x monolithic "
              "(best-of-reps, >= 1.0 required)")
        if not ok:
            rc = rc or 1
    return rc


def update(reps: int) -> None:
    entries = measure(reps, with_naive=True)
    doc = {
        "meta": {
            "reps": reps,
            "tool": "benchmarks/serve_bench.py --update",
            "note": ("median microseconds per call; prefill = one jitted "
                     "prefill incl. cache alloc; decode = "
                     f"{DECODE_STEPS} steady-state greedy steps x batch "
                     f"{BATCH} (naive = eager per-token loop); stream = "
                     f"{len(STREAM_REQS)} staggered requests through "
                     f"{STREAM_SLOTS} continuous-batching slots; "
                     "pipeline_decode[_int8] = the same decode through "
                     "PipelineServeEngine over a mid-model stage cut "
                     "(vs_monolithic = monolithic median / pipelined "
                     "median, a decode throughput ratio, bigger = better; "
                     "raw vs rowwise-int8 boundary wire); "
                     "pipeline_decode_4stage[_overlap] = a 4-stage cut on "
                     "a 4-layer preset, sequential vs the overlapped "
                     "executor (async dispatch + donated boundary "
                     "buffers + micro-batch interleave), with --check "
                     "gating the overlap cell at >= 1.0x monolithic "
                     "best-of-reps; wire_faults = the same "
                     "pipelined decode through the framed BoundaryTransport "
                     f"under a seeded fault schedule at rate {WIRE_LOSS} "
                     "(wire_overhead = vs the transportless pipe); --check "
                     f"compares best-of-reps with a {CHECK_RATIO}x ratio "
                     "tolerance"),
        },
        "entries": entries,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    for name, e in sorted(entries.items()):
        extra = (f" x{e['speedup']} vs naive" if "speedup" in e else "")
        rate = e.get("decode_toks_per_s") or e.get("stream_toks_per_s")
        rate = f", {rate} tok/s" if rate else ""
        print(f"{name}: {e['median_us']:.0f}us{rate}{extra}")


def run(reps: int = 3):
    """benchmarks.run entry point: fast-path timings + committed speedups."""
    from .common import load_bench
    committed = load_bench(BENCH_PATH) or {"entries": {}}
    rows = []
    for name, e in measure(reps, with_naive=False).items():
        c = committed["entries"].get(name, {})
        rows.append({"name": f"serve_bench/{name}",
                     "us_per_call": e["median_us"],
                     "derived": f"committed_speedup={c.get('speedup', '')}"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update", action="store_true",
                    help="measure fast + reference, write BENCH_serve.json")
    ap.add_argument("--check", action="store_true",
                    help=f"fail on >{CHECK_RATIO}x regression vs committed")
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args()
    reps = args.reps or (DEFAULT_REPS if (args.update or args.check) else 3)
    if args.update:
        update(reps)
    elif args.check:
        sys.exit(check(reps))
    else:
        for r in run(reps):
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
