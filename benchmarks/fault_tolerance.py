"""Paper Table 3: fault-tolerance matrix, demonstrated live on the emulator.

Each scenario must complete ALL batches (no data loss) — system IO /
network / single-node / multi-node fault tolerance, plus the beyond-paper
straggler-migration feature.  Every scenario now runs on BOTH the flat
fast event engine and the closure-based reference engine, and the row only
PASSes if their metrics agree exactly (the emulator equivalence contract,
exercised live on every benchmark run).
"""

from __future__ import annotations

from repro.core import partition_and_place, random_geometric_cluster
from repro.emulator import (EmulatorConfig, LinkFault, NodeFault,
                            metrics_identical, simulate)

from .common import build_model, timed

N_BATCH = 40

# name -> {faults: [(kind, stage(s), args...)], cfg: {...}, slow_stage}
SCENARIOS = {
    "network_fault": {
        "faults": [{"link_stages": (0, 1), "t": 10.0, "duration": 15.0}]},
    "single_node_fault": {
        "faults": [{"node_stage": 1, "t": 15.0}]},
    "multi_node_fault": {
        "faults": [{"node_stage": 1, "t": 15.0},
                   {"node_stage": 2, "t": 30.0},
                   {"node_stage": 3, "t": 45.0}]},
    "straggler_migration": {
        "faults": [], "slow_stage": 1, "slow_scale": 0.05,
        "cfg": {"enable_straggler_migration": True}},
}


def _build(spec):
    g = build_model("ResNet50")
    cluster = random_geometric_cluster(14, rng=11)
    plan = partition_and_place(g, cluster, 64e6, n_classes=3, rng=2)
    nodes = list(plan.placement.nodes)
    if spec.get("slow_stage") is not None:
        cluster.compute_scale[nodes[spec["slow_stage"]]] = spec["slow_scale"]
    faults = []
    for f in spec["faults"]:
        if "node_stage" in f:
            faults.append(NodeFault(f["t"], nodes[f["node_stage"]],
                                    f.get("recover")))
        else:
            a, b = f["link_stages"]
            faults.append(LinkFault(f["t"], nodes[a], nodes[b],
                                    f["duration"]))
    cfg = EmulatorConfig(**spec.get("cfg", {}))
    return (cluster, nodes, plan.partition.boundary_sizes,
            plan.partition.compute_flops, faults, cfg)


def run(reps: int = 1):
    rows = []
    for name, spec in SCENARIOS.items():
        # one plan feeds both engines (simulate() never mutates the inputs)
        built = _build(spec)

        def sim(engine, built=built):
            cluster, nodes, bounds, flops, faults, cfg = built
            return simulate(cluster, nodes, bounds, flops, cfg,
                            n_batches=N_BATCH, duration_s=1e6, faults=faults,
                            rng=0, engine=engine)

        m, us = timed(sim, "events")
        ref = sim("reference")
        agree = metrics_identical(m, ref)
        ok = m["completed"] == N_BATCH and agree
        rows.append({"name": f"fault_tolerance/{name}",
                     "us_per_call": us,
                     "derived": f"{'PASS' if ok else 'FAIL'} "
                                f"({m['completed']}/{N_BATCH}, "
                                f"{m['throughput_hz']:.3f} Hz, "
                                f"engines {'agree' if agree else 'DISAGREE'})"})
    return rows
