"""Paper Table 3: fault-tolerance matrix, demonstrated live on the emulator.

Each scenario must complete ALL batches (no data loss) — system IO /
network / single-node / multi-node fault tolerance, plus the beyond-paper
straggler-migration feature.
"""

from __future__ import annotations

import numpy as np

from repro.core import partition_and_place, random_geometric_cluster
from repro.emulator import (EmulatorConfig, FaultInjector, LinkFault,
                            NodeFault, PipelineEmulator)

from .common import build_model, timed


def _fresh(n_classes=3, straggler=False, slow_node=None):
    g = build_model("ResNet50")
    cluster = random_geometric_cluster(14, rng=11)
    if slow_node is not None:
        cluster.compute_scale[slow_node] = 0.05
    plan = partition_and_place(g, cluster, 64e6, n_classes=n_classes, rng=2)
    cfg = EmulatorConfig(enable_straggler_migration=straggler)
    emu = PipelineEmulator(cluster, plan.placement.nodes,
                           plan.partition.boundary_sizes,
                           plan.partition.compute_flops, cfg)
    return plan, emu


N_BATCH = 40


def scenario_network_fault():
    plan, emu = _fresh()
    FaultInjector(emu).schedule([
        LinkFault(10.0, plan.placement.nodes[0], plan.placement.nodes[1], 15.0)])
    return emu.run(N_BATCH, 1e6)


def scenario_single_node():
    plan, emu = _fresh()
    FaultInjector(emu).schedule([NodeFault(15.0, plan.placement.nodes[1])])
    return emu.run(N_BATCH, 1e6)


def scenario_multi_node():
    plan, emu = _fresh()
    FaultInjector(emu).schedule([
        NodeFault(15.0, plan.placement.nodes[1]),
        NodeFault(30.0, plan.placement.nodes[2]),
        NodeFault(45.0, plan.placement.nodes[3])])
    return emu.run(N_BATCH, 1e6)


def scenario_straggler():
    plan, emu = _fresh(straggler=True,
                       slow_node=None)
    # make the stage-1 node a 20x straggler after placement
    emu.cluster.compute_scale[emu.stages[1].node] = 0.05
    for st in emu.stages[1:]:
        st.compute_s = st.compute_s  # recompute below
    emu.stages[1].compute_s /= 0.05
    return emu.run(N_BATCH, 1e6)


SCENARIOS = {
    "network_fault": scenario_network_fault,
    "single_node_fault": scenario_single_node,
    "multi_node_fault": scenario_multi_node,
    "straggler_migration": scenario_straggler,
}


def run(reps: int = 1):
    rows = []
    for name, fn in SCENARIOS.items():
        m, us = timed(fn)
        ok = m["completed"] == N_BATCH
        rows.append({"name": f"fault_tolerance/{name}",
                     "us_per_call": us,
                     "derived": f"{'PASS' if ok else 'FAIL'} "
                                f"({m['completed']}/{N_BATCH}, "
                                f"{m['throughput_hz']:.3f} Hz)"})
    return rows
