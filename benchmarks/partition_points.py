"""Paper Fig. 3: candidate partition points per model (>=25 for most;
NASNet-style cross-links admit none in the body)."""

from __future__ import annotations

from repro.configs.paper_cnns import PAPER_MODELS, nasnet_like

from .common import timed


def run(reps: int = 1):
    rows = []
    for name, fn in PAPER_MODELS.items():
        g = fn()
        pts, us = timed(g.candidate_partition_points)
        rows.append({"name": f"partition_points/{name}", "us_per_call": us,
                     "derived": len(pts)})
    g = nasnet_like()
    pts, us = timed(g.candidate_partition_points)
    lp = g.longest_path_depths()
    interior = [p for p in pts
                if 2 < lp[p] < max(lp.values()) - 2]
    rows.append({"name": "partition_points/NASNet-like(interior)",
                 "us_per_call": us, "derived": len(interior)})
    return rows
